"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 50 --batch 8 --seq 128 --mode fmi --allreduce ring

Full-size archs on the production mesh are exercised via dryrun.py (this
container has one real device); ``--reduced`` trains the smoke-sized config
of the same family for real.  Supports both distribution modes, gradient
compression, ZeRO-1, checkpoint/restart (``--ckpt-dir``), and resumes
automatically from the latest committed checkpoint.

Elastic demo: ``--elastic`` arms the runtime's heal path, and
``--kill-rank R --kill-at-step N`` injects a deterministic failure —
at step N rank R is declared dead, the :class:`ElasticController` runs
quiesce → regroup (``--regroup`` strategy) → reshard (latest committed
checkpoint, or re-init when none), and the loop resumes at the restored
step::

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 20 --ckpt-dir /tmp/ck --ckpt-every 5 \
        --elastic --kill-rank 0 --kill-at-step 12
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from .. import compat, configs
from ..checkpoint import CheckpointManager
from ..data.pipeline import DataConfig, synthetic_batch
from ..models import lm
from ..optim.optimizer import OptConfig
from ..training.train_step import TrainConfig, init_opt_state, make_train_step, place_state
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="xla", choices=["xla", "fmi"])
    ap.add_argument("--allreduce", default="auto")
    ap.add_argument("--schedule", default="blocking", choices=["blocking", "bucketed"],
                    help="gradient sync: fused blocking collective vs "
                    "CommScheduler bucketed-overlap requests")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="pin the scheduler bucket size (MB); default lets "
                    "selector.bucket_plan choose from the α-β model")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out-json", default="")
    ap.add_argument("--elastic", action="store_true",
                    help="arm the elastic heal path (membership + controller)")
    ap.add_argument("--regroup", default="pow2_floor",
                    choices=["auto", "pow2_floor", "ring", "recursive_doubling"],
                    help="group-build strategy for heals (algorithms.build_group)")
    ap.add_argument("--kill-rank", type=int, default=None,
                    help="inject: declare this rank dead at --kill-at-step")
    ap.add_argument("--kill-at-step", type=int, default=None)
    from .sanitize_cli import add_sanitize_args, arm, emit

    add_sanitize_args(ap)
    args = ap.parse_args()
    san = arm(args)  # before the first communicator is built

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    tcfg = TrainConfig(
        mode=args.mode,
        microbatches=args.microbatches,
        optimizer=OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5 + 1)),
        allreduce=args.allreduce,
        schedule=args.schedule,
        bucket_mb=args.bucket_mb,
        zero1=args.zero1,
        compression=args.compression,
    )
    step_fn, ax, pspecs = make_train_step(cfg, tcfg, mesh, multi_pod=False)
    dcfg = DataConfig()

    with compat.set_mesh(mesh):
        params = lm.init_params(cfg, jax.random.key(0))
        if args.zero1 and args.mode == "fmi":
            from ..core.communicator import Communicator
            from ..training import zero1 as z1

            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            comm = Communicator(axes=ax.data, sizes=tuple(sizes[a] for a in ax.data))
            layout = z1.make_layout(params, comm.size)
            opt_state = z1.zero1_init(params, layout, comm, tcfg.optimizer.state_dtype)
        else:
            opt_state = init_opt_state(cfg, tcfg, params)
        if not args.zero1:
            params, opt_state = place_state(mesh, params, opt_state, pspecs, tcfg)

        start = 0
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt is not None:
            try:
                state, start = ckpt.restore_latest({"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                print(f"resumed from step {start}")
            except FileNotFoundError:
                pass

        # elastic runtime: membership + controller around the loop (heals
        # rebuild the step function and reshard from the latest commit)
        controller = None
        state = {"params": params, "opt": opt_state}
        if args.elastic:
            from ..runtime import ElasticController, GroupError, Membership

            n_ranks = args.data_axis * args.model_axis
            membership = Membership(expected=n_ranks)
            for r in range(n_ranks):
                membership.join(r)

            def rebuild(dp):
                nonlocal step_fn
                # single-host smoke path: the mesh keeps its devices; the
                # step function is rebuilt (multi-device rescale is
                # exercised by Trainer and tests/test_elastic.py)
                step_fn, _, _ = make_train_step(cfg, tcfg, mesh, multi_pod=False)

            def restore():
                if ckpt is not None:
                    ckpt.wait()
                    try:
                        target = {"params": state["params"], "opt": state["opt"]}
                        restored, s = ckpt.restore_latest(target)
                        state.update(restored)
                        return s
                    except FileNotFoundError:
                        pass
                print("heal: no committed checkpoint; continuing from live "
                      "state (bounded-staleness restart)")
                return state["step_cursor"]

            controller = ElasticController(
                membership=membership, rebuild=rebuild, restore=restore,
                strategy=args.regroup,
            )

        history = []
        t_start = time.perf_counter()
        step, end = start, start + args.steps
        while step < end:
            state["step_cursor"] = step
            if controller is not None:
                try:
                    for r in sorted(membership.group()):
                        membership.heartbeat(r)
                    if args.kill_rank is not None and step == args.kill_at_step:
                        membership.mark_failed(args.kill_rank)
                        args.kill_rank = None  # one-shot injection
                    membership.check_alive()
                except GroupError as e:
                    print(f"step {step:5d} FAILURE: {e}")
                    step = controller.heal()
                    params, opt_state = state["params"], state["opt"]
                    h = controller.history[-1]
                    print(f"healed: regrouped to dp={h['dp']} "
                          f"({h['strategy']}, spares={h['spares']}), "
                          f"resuming at step {step}")
                    continue
            batch = jax.tree.map(
                jax.numpy.asarray,
                synthetic_batch(dcfg, cfg, args.batch, args.seq, step),
            )
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, "time_s": dt, **m})
            state["params"], state["opt"] = params, opt_state
            if step % args.log_every == 0 or step == end - 1:
                print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                      f"lr {m['lr']:.2e} gnorm {m.get('grad_norm', 0):.2f} {dt*1e3:.0f}ms")
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                world = (len(membership.group()) if controller is not None
                         else args.data_axis * args.model_axis)
                ckpt.save_async(
                    {"params": params, "opt": opt_state}, step + 1,
                    extra={"generation": controller.generation if controller
                           else 0, "world": world},
                )
            step += 1
        if ckpt is not None:
            ckpt.wait()

    total = time.perf_counter() - t_start
    first, last = history[0]["ce"], history[-1]["ce"]
    print(f"done: {args.steps} steps in {total:.1f}s; ce {first:.3f} -> {last:.3f}")
    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(history, f)
    emit(san, args)


if __name__ == "__main__":
    main()
