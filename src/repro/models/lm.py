"""Unified language model: one block machinery, ten architectures.

Every arch is a stack of *scan groups* (``cfg.group_size`` layers per group,
``cfg.n_groups`` groups).  Group parameters are stacked on a leading G axis
and the stack lowers as a single ``jax.lax.scan`` (small HLO, fast SPMD
partitioning at 100-layer scale) with optional remat.

Families and their group bodies:

    dense / audio : [attn -> mlp]
    moe           : [attn|mla -> moe]
    vlm           : [4 x (attn -> mlp), cross-attn -> mlp]
    ssm (xlstm)   : [(k-1) x mLSTM, sLSTM]
    hybrid        : [parallel(attn, ssd) -> mlp]

Entry points: :func:`init_params`, :func:`param_specs`, :func:`forward`,
:func:`loss_fn`, :func:`init_cache`, :func:`prefill`, :func:`decode_step`,
:func:`input_specs`, :func:`count_params`.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as ATT
from . import mla as MLA
from . import moe as MOE
from . import ssm as SSM
from .config import ModelConfig
from .layers import Axes, dense_init, embed_init, rmsnorm


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 128) * 128


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_group(cfg: ModelConfig, key):
    """Parameters of ONE scan group (un-stacked)."""
    fam = cfg.family
    D = cfg.d_model
    ks = iter(jax.random.split(key, 64))
    nx = lambda: next(ks)  # noqa: E731
    ones = lambda: jnp.ones((D,), cfg.pdtype)  # noqa: E731

    if fam in ("dense", "audio"):
        return {
            "ln1": ones(), "attn": ATT.attn_init(nx(), cfg),
            "ln2": ones(), "mlp": MOE.mlp_init(nx(), cfg),
        }
    if fam == "moe":
        mixer = (
            {"mla": MLA.mla_init(nx(), cfg)}
            if cfg.mla
            else {"attn": ATT.attn_init(nx(), cfg)}
        )
        g = {"ln1": ones(), **mixer, "ln2": ones(), "moe": MOE.moe_init(nx(), cfg)}
        n_dense = cfg.moe.every_k - 1  # llama4: dense layers between MoE layers
        if n_dense:
            denses = [
                {
                    "ln1": ones(), "attn": ATT.attn_init(nx(), cfg),
                    "ln2": ones(), "mlp": MOE.mlp_init(nx(), cfg),
                }
                for _ in range(n_dense)
            ]
            g["dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *denses)
        return g
    if fam == "vlm":
        n_self = cfg.vlm.cross_every - 1
        selfs = [
            {
                "ln1": ones(), "attn": ATT.attn_init(nx(), cfg),
                "ln2": ones(), "mlp": MOE.mlp_init(nx(), cfg),
            }
            for _ in range(n_self)
        ]
        cross = {
            "ln1": ones(), "attn": ATT.attn_init(nx(), cfg, cross=True),
            "ln2": ones(), "mlp": MOE.mlp_init(nx(), cfg),
        }
        return {"self": jax.tree.map(lambda *xs: jnp.stack(xs), *selfs), "cross": cross}
    if fam == "ssm":
        n_m = cfg.ssm.slstm_every - 1
        ms = [SSM.mlstm_init(nx(), cfg) for _ in range(n_m)]
        return {
            "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *ms),
            "slstm": SSM.slstm_init(nx(), cfg),
        }
    if fam == "hybrid":
        hd = cfg.hd
        return {
            "ln1": ones(),
            "attn": ATT.attn_init(nx(), cfg),
            "ssd": SSM.ssd_init(nx(), cfg),
            "wo_ssd": dense_init(nx(), (D, D), cfg.pdtype),
            "ln2": ones(),
            "mlp": MOE.mlp_init(nx(), cfg),
        }
    raise ValueError(fam)


def init_params(cfg: ModelConfig, key):
    kg, ke, kh, km = jax.random.split(key, 4)
    Vp = padded_vocab(cfg)
    group_keys = jax.random.split(kg, cfg.n_groups)
    groups = jax.vmap(lambda k: _init_group(cfg, k))(group_keys)
    params: dict[str, Any] = {
        "groups": groups,
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if cfg.family == "audio":
        params["mask_emb"] = embed_init(ke, (cfg.d_model,), cfg.pdtype)
        params["head"] = dense_init(kh, (cfg.d_model, Vp), cfg.pdtype)
    else:
        params["embed"] = embed_init(ke, (Vp, cfg.d_model), cfg.pdtype)
        if not cfg.tie_embeddings:
            params["head"] = dense_init(kh, (cfg.d_model, Vp), cfg.pdtype)
    return params


# ---------------------------------------------------------------------------
# sharding specs (FSDP over ax.fsdp, TP over ax.model; auto-drops axes that
# do not divide)
# ---------------------------------------------------------------------------

# matmul weights whose LAST dim is the TP (output) dim
_TP_OUT = {
    "wq", "wk", "wv", "up", "gate", "wx", "ffn_up", "in_proj", "wq_b", "wk_b",
    "wv_b", "head",
}
# matmul weights whose FIRST (non-stack) dim is the TP dim
_TP_IN = {"wo", "down", "ffn_down", "wo_ssd"}


def param_specs(cfg: ModelConfig, ax: Axes, mesh_shape: dict[str, int] | None = None):
    """PartitionSpec tree matching init_params' structure.

    TP-dim over ``ax.model`` (when set and divisible), FSDP-dim over
    ``ax.fsdp`` (a tuple — pure-DP policies shard weights over both mesh
    axes).  Axes that do not divide the dim are dropped (replicated)."""

    fsdp = ax.fsdp if len(ax.fsdp) != 1 else ax.fsdp[0]

    def ok_m(dim: int) -> bool:
        return ax.model is not None and ax.divides(dim, ax.model) and ax.axsize(ax.model) > 1

    def ok_f(dim: int) -> bool:
        return len(ax.fsdp) > 0 and ax.divides(dim, ax.fsdp) and ax.axsize(ax.fsdp) > 1

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1] if names else ""
        shape = leaf.shape
        stacked = "groups" in names  # leading G axis (and E axis for experts)
        base = [None] * len(shape)

        if name == "embed":
            if ok_m(shape[0]):
                base[0] = ax.model
            elif ok_f(shape[1]):
                base[1] = fsdp
            return P(*base)
        # expert tensors [G, E, D, F] / [G, E, F, D]
        if len(shape) == 4 and stacked and name in ("gate", "up", "down") and "moe" in names:
            if ok_m(shape[1]):
                base[1] = ax.model
            if ok_f(shape[2]):
                base[2] = fsdp
            return P(*base)
        if name in _TP_OUT and len(shape) >= 2:
            i, o = len(shape) - 2, len(shape) - 1
            if ok_m(shape[o]):
                base[o] = ax.model
            if ok_f(shape[i]):
                base[i] = fsdp
            return P(*base)
        if name in _TP_IN and len(shape) >= 2:
            i, o = len(shape) - 2, len(shape) - 1
            if ok_m(shape[i]):
                base[i] = ax.model
            if ok_f(shape[o]):
                base[o] = fsdp
            return P(*base)
        # norms, gates, convs, routers, biases: replicate (tiny)
        return P(*base)

    return jax.tree_util.tree_map_with_path(spec_for, jax.eval_shape(lambda: init_params(cfg, jax.random.key(0))))


# ---------------------------------------------------------------------------
# group body (train / prefill / decode share one code path)
# ---------------------------------------------------------------------------


def _dense_block(p, x, cfg, ax, cache, decode_pos, positions, kv_src=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = ATT.attn_apply(
        p["attn"], h, cfg, ax, kv_src=kv_src, positions=positions,
        cache=cache, decode_pos=decode_pos,
    )
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + MOE.mlp_apply(p["mlp"], h, cfg, ax)
    return ax.act_btd(x), cache


def _apply_group(gp, x, cfg: ModelConfig, ax: Axes, cache_g, decode_pos, positions, vis):
    """One scan group.  Returns (x, aux, new_cache_g)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache_g

    if fam in ("dense", "audio"):
        c = None if cache_g is None else cache_g["attn"]
        x, c = _dense_block(gp, x, cfg, ax, c, decode_pos, positions)
        new_cache = None if cache_g is None else {"attn": c}

    elif fam == "moe":
        n_dense = cfg.moe.every_k - 1
        ds = [] if cache_g is not None else None
        for i in range(n_dense):  # dense interleave layers (llama4)
            dp = jax.tree.map(lambda a, i=i: a[i], gp["dense"])
            c = None if cache_g is None else jax.tree.map(lambda a, i=i: a[i], cache_g["dense"])
            x, c = _dense_block(dp, x, cfg, ax, c, decode_pos, positions)
            if ds is not None:
                ds.append(c)
        h = rmsnorm(x, gp["ln1"], cfg.norm_eps)
        c = None if cache_g is None else cache_g["attn"]
        if cfg.mla:
            a, c = MLA.mla_apply(
                gp["mla"], h, cfg, ax, positions=positions, cache=c,
                decode_pos=decode_pos,
            )
        else:
            a, c = ATT.attn_apply(
                gp["attn"], h, cfg, ax, positions=positions, cache=c,
                decode_pos=decode_pos,
            )
        x = x + a
        h = rmsnorm(x, gp["ln2"], cfg.norm_eps)
        mo, aux = MOE.moe_apply(gp["moe"], h, cfg, ax)
        x = ax.act_btd(x + mo)
        if cache_g is not None:
            new_cache = {"attn": c}
            if ds:
                new_cache["dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ds)

    elif fam == "vlm":
        n_self = cfg.vlm.cross_every - 1
        cs = [] if cache_g is not None else None
        for i in range(n_self):
            sp = jax.tree.map(lambda a, i=i: a[i], gp["self"])
            c = None if cache_g is None else jax.tree.map(lambda a, i=i: a[i], cache_g["self"])
            x, c = _dense_block(sp, x, cfg, ax, c, decode_pos, positions)
            if cs is not None:
                cs.append(c)
        cp = gp["cross"]
        h = rmsnorm(x, cp["ln1"], cfg.norm_eps)
        a, _ = ATT.attn_apply(cp["attn"], h, cfg, ax, kv_src=vis)
        x = x + a
        h = rmsnorm(x, cp["ln2"], cfg.norm_eps)
        x = ax.act_btd(x + MOE.mlp_apply(cp["mlp"], h, cfg, ax))
        if cs is not None:
            new_cache = {"self": jax.tree.map(lambda *xs: jnp.stack(xs), *cs)}

    elif fam == "ssm":
        n_m = cfg.ssm.slstm_every - 1
        ms = [] if cache_g is not None else None
        for i in range(n_m):
            mp = jax.tree.map(lambda a, i=i: a[i], gp["mlstm"])
            st = None if cache_g is None else jax.tree.map(lambda a, i=i: a[i], cache_g["mlstm"])
            x, st = SSM.mlstm_apply(mp, x, cfg, ax, state=st)
            if ms is not None:
                ms.append(st)
        st = None if cache_g is None else cache_g["slstm"]
        x, st_new = SSM.slstm_apply(gp["slstm"], x, cfg, ax, state=st)
        if cache_g is not None:
            new_cache = {
                "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *ms),
                "slstm": st_new,
            }

    elif fam == "hybrid":
        h = rmsnorm(x, gp["ln1"], cfg.norm_eps)
        ca = None if cache_g is None else cache_g["attn"]
        a, ca = ATT.attn_apply(
            gp["attn"], h, cfg, ax, positions=positions, cache=ca,
            decode_pos=decode_pos,
        )
        cs = None if cache_g is None else cache_g["ssd"]
        y, cs = SSM.ssd_apply(gp["ssd"], h, cfg, ax, state=cs)
        mixed = 0.5 * a + 0.5 * (y @ gp["wo_ssd"].astype(cfg.adtype))
        x = x + mixed
        h = rmsnorm(x, gp["ln2"], cfg.norm_eps)
        x = ax.act_btd(x + MOE.mlp_apply(gp["mlp"], h, cfg, ax))
        if cache_g is not None:
            new_cache = {"attn": ca, "ssd": cs}

    else:
        raise ValueError(fam)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, ax: Axes, batch):
    dt = cfg.adtype
    if cfg.family == "audio":
        x = batch["features"].astype(dt)
        mask = batch["mask"][..., None]
        x = jnp.where(mask, params["mask_emb"].astype(dt), x)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    return ax.act_btd(x)


def _head_out(params, cfg: ModelConfig, ax: Axes, x):
    dt = cfg.adtype
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family != "audio" and cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(dt))
    else:
        logits = x @ params["head"].astype(dt)
    return ax.act_btv(logits)


def forward(
    params,
    cfg: ModelConfig,
    ax: Axes,
    batch: dict,
    cache=None,
    decode_pos=None,
):
    """Returns (logits [B,T,Vp], aux_loss, new_cache)."""
    x = _embed_in(params, cfg, ax, batch)
    T = x.shape[1]
    positions = (
        jnp.arange(T)
        if decode_pos is None
        else decode_pos + jnp.arange(T)
    )
    vis = batch.get("vision")
    if vis is not None:
        vis = vis.astype(cfg.adtype)

    def body(carry, xs):
        xc, auxc = carry
        gp, cg = xs if cache is not None else (xs, None)
        xc, aux_g, ncg = _apply_group(gp, xc, cfg, ax, cg, decode_pos, positions, vis)
        return (xc, auxc + aux_g), ncg

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        xs = (params["groups"], cache) if cache is not None else params["groups"]
        (x, aux), new_cache = jax.lax.scan(body, (x, aux0), xs)
    else:
        new_groups = []
        aux = aux0
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a, g=g: a[g], params["groups"])
            cg = None if cache is None else jax.tree.map(lambda a, g=g: a[g], cache)
            xs = (gp, cg) if cache is not None else gp
            (x, aux), ncg = body((x, aux), xs)
            new_groups.append(ncg)
        new_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_groups)
            if cache is not None
            else None
        )

    logits = _head_out(params, cfg, ax, x)
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(logits, labels, cfg: ModelConfig, aux=0.0, z_loss: float = 1e-4,
            aux_weight: float = 1e-2, chunk: int = 512):
    """Cross-entropy with fused label pick (sharded-vocab safe), z-loss,
    MoE aux loss.  ``labels < 0`` positions are masked out.

    Computed in **sequence chunks** under remat: the f32 view of the logits
    only ever exists for [B, chunk, V] at a time — at a 202k vocab the
    whole-sequence f32 temporaries alone are ~6.6 GiB/chip (llama4 train
    cell went 20.0 -> fits after this change)."""
    B, S, Vp = logits.shape

    @jax.checkpoint
    def chunk_stats(lg, lb):
        lf = lg.astype(jnp.float32)
        if Vp != cfg.vocab_size:  # mask vocab padding out of the softmax
            iota_v = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 2)
            lf = jnp.where(iota_v < cfg.vocab_size, lf, -1e30)
        lse = jax.nn.logsumexp(lf, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 2)
        pick = jnp.sum(jnp.where(iota == lb[..., None], lf, 0.0), axis=-1)
        mask = (lb >= 0).astype(jnp.float32)
        return (
            jnp.sum((lse - pick) * mask),
            jnp.sum(jnp.square(lse) * mask),
            jnp.sum(mask),
        )

    c = min(chunk, S)
    if S % c:
        c = S  # odd lengths: single chunk
    nc = S // c
    if nc > 1:
        lg = jnp.moveaxis(logits.reshape(B, nc, c, Vp), 1, 0)
        lb = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
        ce_s, zl_s, n_s = jax.lax.map(lambda t: chunk_stats(*t), (lg, lb))
        ce_sum, zl_sum, n = ce_s.sum(), zl_s.sum(), n_s.sum()
    else:
        ce_sum, zl_sum, n = chunk_stats(logits, labels)
    n = jnp.maximum(n, 1.0)
    ce = ce_sum / n
    zl = zl_sum / n
    return ce + z_loss * zl + aux_weight * aux, ce


# ---------------------------------------------------------------------------
# caches / serving
# ---------------------------------------------------------------------------


def _init_group_cache(cfg: ModelConfig, batch: int, max_len: int):
    fam = cfg.family
    if fam in ("dense", "audio"):
        return {"attn": ATT.init_cache(cfg, batch, max_len)}
    if fam == "moe":
        c = {
            "attn": MLA.mla_init_cache(cfg, batch, max_len)
            if cfg.mla
            else ATT.init_cache(cfg, batch, max_len)
        }
        n_dense = cfg.moe.every_k - 1
        if n_dense:
            one = ATT.init_cache(cfg, batch, max_len)
            c["dense"] = jax.tree.map(lambda a: jnp.stack([a] * n_dense), one)
        return c
    if fam == "vlm":
        n_self = cfg.vlm.cross_every - 1
        one = ATT.init_cache(cfg, batch, max_len)
        return {"self": jax.tree.map(lambda a: jnp.stack([a] * n_self), one)}
    if fam == "ssm":
        n_m = cfg.ssm.slstm_every - 1
        m = SSM.mlstm_init_state(cfg, batch)
        return {
            "mlstm": jax.tree.map(lambda a: jnp.stack([a] * n_m), m),
            "slstm": SSM.slstm_init_state(cfg, batch),
        }
    if fam == "hybrid":
        kind = "ring" if cfg.sliding_window else "full"
        return {
            "attn": ATT.init_cache(cfg, batch, max_len, kind=kind),
            "ssd": SSM.ssd_init_state(cfg, batch),
        }
    raise ValueError(fam)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    one = _init_group_cache(cfg, batch, max_len)
    return jax.tree.map(lambda a: jnp.stack([a] * cfg.n_groups), one)


def cache_specs(cfg: ModelConfig, ax: Axes, batch: int = 1024, max_len: int = 32768):
    """PartitionSpec tree for the cache: batch over data axes, kv-heads (or,
    failing divisibility, the sequence dim) over the model axis.  ``batch``/
    ``max_len`` must be the real serving dims (divisibility decisions)."""

    def spec_for(path, leaf):
        shape = leaf.shape  # [G, B, ...] or [G, n, B, ...]
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        base = [None] * len(shape)
        # find the batch dim: first dim after leading stack dims that is not
        # a small stack axis — by construction dim 1 unless under 'self'/'mlstm'
        bdim = 2 if any(n in ("self", "mlstm", "dense") for n in names) else 1
        if bdim < len(base) and ax.data and ax.divides(shape[bdim], ax.data):
            base[bdim] = ax.data
        # shard kv-head dim over model if divisible; otherwise shard the
        # sequence dim (sequence-parallel decode attention — the partial
        # softmax reductions partition under GSPMD)
        tp_ok = ax.model is not None and ax.axsize(ax.model) > 1
        if names[-1] in ("k", "v") and len(shape) >= bdim + 3 and tp_ok:
            hdim = len(shape) - 2
            sdim = bdim + 1
            if ax.divides(shape[hdim], ax.model):
                base[hdim] = ax.model
            elif ax.divides(shape[sdim], ax.model):
                base[sdim] = ax.model
        elif names[-1] in ("ckv", "kpe", "pos") and len(shape) >= bdim + 2 and tp_ok:
            sdim = bdim + 1
            if ax.divides(shape[sdim], ax.model):
                base[sdim] = ax.model
        return P(*base)

    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def prefill(params, cfg: ModelConfig, ax: Axes, batch: dict, cache):
    """Fill the cache from a prompt; returns (last_logits, cache)."""
    logits, _aux, cache = forward(params, cfg, ax, batch, cache=cache, decode_pos=0)
    return logits[:, -1], cache


def decode_step(params, cfg: ModelConfig, ax: Axes, tokens, pos, cache, extra=None):
    """One decode step: tokens [B, 1], pos scalar -> (next_token, cache)."""
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    logits, _aux, cache = forward(
        params, cfg, ax, batch, cache=cache, decode_pos=pos
    )
    nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    return nxt.astype(jnp.int32), cache


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct stand-ins for one *training* batch."""
    sd = jax.ShapeDtypeStruct
    specs = {}
    if cfg.family == "audio":
        specs["features"] = sd((batch, seq, cfg.d_model), jnp.bfloat16)
        specs["mask"] = sd((batch, seq), jnp.bool_)
        specs["labels"] = sd((batch, seq), jnp.int32)
    else:
        specs["tokens"] = sd((batch, seq), jnp.int32)
        specs["labels"] = sd((batch, seq), jnp.int32)
    if cfg.family == "vlm":
        specs["vision"] = sd((batch, cfg.vlm.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def input_spec_shardings(cfg: ModelConfig, ax: Axes) -> dict:
    out = {}
    names = (
        ["features", "mask", "labels"] if cfg.family == "audio" else ["tokens", "labels"]
    )
    for n in names:
        out[n] = P(ax.data, None, None) if n == "features" else P(ax.data, None)
    if cfg.family == "vlm":
        out["vision"] = P(ax.data, None, None)
    return out


# ---------------------------------------------------------------------------
# parameter counting (exact, via eval_shape)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    total = 0

    def visit(path, leaf):
        nonlocal total
        n = math.prod(leaf.shape)
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if (
            active_only
            and cfg.moe
            and "moe" in names
            and names[-1] in ("gate", "up", "down")
        ):
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total
