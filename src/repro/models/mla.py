"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill run the *unabsorbed* form (materialize per-head K/V from the
shared latent, then flash attention).  Decode runs the *absorbed* form: the
up-projections are folded into the query/output sides so attention works
directly against the compressed latent cache —

    cache:  c_kv [B, S, kv_lora]  +  k_pe [B, S, qk_rope]         (shared!)
    score:  (q_nope Wuk) · c_kv   +   q_pe · k_pe
    value:  (probs · c_kv) Wuv

so the per-token cache is kv_lora + qk_rope = 576 values instead of
2·H·hd = 32768 — a 57× KV-cache compression, which is the reason this arch
exists.  The 32k-decode dry-run cell uses exactly this path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import Axes, apply_rope, dense_init, rmsnorm


def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (D, m.q_lora), cfg.pdtype),
        "q_norm": jnp.ones((m.q_lora,), cfg.pdtype),
        "wq_b": dense_init(ks[1], (m.q_lora, H * (m.qk_nope + m.qk_rope)), cfg.pdtype),
        "wkv_a": dense_init(ks[2], (D, m.kv_lora + m.qk_rope), cfg.pdtype),
        "kv_norm": jnp.ones((m.kv_lora,), cfg.pdtype),
        "wk_b": dense_init(ks[3], (m.kv_lora, H * m.qk_nope), cfg.pdtype),
        "wv_b": dense_init(ks[4], (m.kv_lora, H * m.v_dim), cfg.pdtype),
        "wo": dense_init(ks[5], (H * m.v_dim, D), cfg.pdtype),
    }


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora), cfg.adtype),
        "kpe": jnp.zeros((batch, max_len, m.qk_rope), cfg.adtype),
    }


def _latent(p, x, cfg: ModelConfig, positions):
    """x -> (c_kv [B,T,kv_lora] normalized, k_pe [B,T,rope] roped)."""
    m = cfg.mla
    dt = cfg.adtype
    kv_a = x @ p["wkv_a"].astype(dt)
    c_kv, k_pe = kv_a[..., : m.kv_lora], kv_a[..., m.kv_lora :]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe


def _queries(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    H = cfg.n_heads
    dt = cfg.adtype
    q = rmsnorm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(dt)).reshape(*x.shape[:2], H, m.qk_nope + m.qk_rope)
    q_nope, q_pe = q[..., : m.qk_nope], q[..., m.qk_nope :]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_apply(
    p,
    x,
    cfg: ModelConfig,
    ax: Axes,
    *,
    positions=None,
    cache=None,
    decode_pos=None,
    backend: str = "auto",
):
    m = cfg.mla
    B, T, D = x.shape
    H = cfg.n_heads
    dt = cfg.adtype
    if positions is None:
        positions = (
            jnp.arange(T) if decode_pos is None else jnp.full((T,), decode_pos)
        )

    q_nope, q_pe = _queries(p, x, cfg, positions)
    q_nope, q_pe = ax.act_bthd(q_nope), ax.act_bthd(q_pe)
    c_kv, k_pe = _latent(p, x, cfg, positions)

    new_cache = cache
    if cache is not None:
        at = 0 if decode_pos is None else decode_pos
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv, at, 1),
            "kpe": jax.lax.dynamic_update_slice_in_dim(cache["kpe"], k_pe, at, 1),
        }

    scale = (m.qk_nope + m.qk_rope) ** -0.5
    # absorbed path only for single-token decode; prefill attends within x
    if decode_pos is not None and T == 1 and cache is not None:
        # --- absorbed decode against the latent cache ---
        wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora, H, m.qk_nope)
        wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora, H, m.v_dim)
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, wk_b)  # [B,T,H,kv_lora]
        ckv_c, kpe_c = new_cache["ckv"], new_cache["kpe"]  # [B,S,...]
        s = (
            jnp.einsum("bthl,bsl->bhts", q_lat.astype(jnp.float32), ckv_c.astype(jnp.float32))
            + jnp.einsum("bthr,bsr->bhts", q_pe.astype(jnp.float32), kpe_c.astype(jnp.float32))
        ) * scale
        S = ckv_c.shape[1]
        k_pos = jnp.arange(S)[None, None, None, :]
        s = jnp.where(k_pos <= decode_pos, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhts,bsl->bthl", pr, ckv_c.astype(jnp.float32))  # latent ctx
        out = jnp.einsum("bthl,lhv->bthv", ctx.astype(dt), wv_b)  # [B,T,H,v]
    else:
        # --- unabsorbed train/prefill: materialize K/V, flash attention ---
        k_nope = (c_kv @ p["wk_b"].astype(dt)).reshape(B, T, H, m.qk_nope)
        v = (c_kv @ p["wv_b"].astype(dt)).reshape(B, T, H, m.v_dim)
        k_nope, v = ax.act_bthd(k_nope), ax.act_bthd(v)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, T, H, m.qk_rope))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = ops.flash_attention(
            jnp.swapaxes(q, 1, 2),
            jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2),
            causal=True, backend=backend,
        )
        out = jnp.swapaxes(out, 1, 2)

    out = ax.act_bthd(out)
    out = out.reshape(B, T, H * m.v_dim) @ p["wo"].astype(dt)
    return ax.act_btd(out), new_cache
