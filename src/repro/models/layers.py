"""Primitive layers: init helpers, RMSNorm, RoPE, sharding constraints."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Mesh-axis context: which mesh axes mean "batch" and "model"
# ---------------------------------------------------------------------------


class Axes:
    """Named-axis context threaded through the model for sharding constraints.

    ``data``: tuple of mesh axes the batch is sharded over (('data',) on one
    pod, ('pod','data') across pods).  ``model``: the tensor-parallel axis.
    ``fsdp``: axis weights are additionally sharded over (ZeRO-3-style);
    usually the in-pod 'data' axis — never the cross-pod axis (DCN).
    """

    def __init__(self, data=("data",), model="model", fsdp="data", enabled=True,
                 sizes: dict | None = None, seq=None):
        self.data = tuple(data)
        self.model = model  # TP axis name, or None (pure-DP policy)
        self.fsdp = (
            tuple(fsdp) if isinstance(fsdp, (tuple, list)) else ((fsdp,) if fsdp else ())
        )
        self.enabled = enabled
        self.sizes = sizes or {}
        # Megatron-style sequence parallelism: residual-stream activations
        # (and therefore remat carries) sharded seq-over-model between blocks
        self.seq = seq

    def axsize(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            out = 1
            for a in axis:
                out *= self.sizes.get(a, 1)
            return out
        return self.sizes.get(axis, 1)

    def divides(self, dim: int, axis) -> bool:
        return dim % self.axsize(axis) == 0

    def constrain(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    # common activation constraints
    def act_btd(self, x):  # [B, T, D]
        s = self.seq if self.seq and self.divides(x.shape[1], self.seq) else None
        return self.constrain(x, P(self.data, s, None))

    def act_bthd(self, x):  # [B, T, H, hd] — heads tensor-parallel
        m = self.model if self.model and self.divides(x.shape[2], self.model) else None
        return self.constrain(x, P(self.data, None, m, None))

    def act_btf(self, x):  # [B, T, F] — mlp hidden tensor-parallel
        m = self.model if self.model and self.divides(x.shape[-1], self.model) else None
        return self.constrain(x, P(self.data, None, m))

    def act_btv(self, x):  # [B, T, V] — vocab tensor-parallel
        m = self.model if self.model and self.divides(x.shape[-1], self.model) else None
        return self.constrain(x, P(self.data, None, m))


NO_SHARD = Axes(enabled=False)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], dtype, fan_in: int | None = None):
    """Truncated-normal with 1/sqrt(fan_in) scaling (last-but-one dim)."""
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = fan**-0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * 0.02).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# Rotary position embeddings (rotate-half convention)
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))


def apply_rope(x, positions, theta: float):
    """x: [B, T, H, hd]; positions: [T] or [B, T] absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, hd/2]
        ang = ang[None, :, None, :]  # [1, T, 1, hd/2]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, hd/2]
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
