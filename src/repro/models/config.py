"""Model configuration schema for the architecture zoo.

One unified block structure covers all ten assigned architectures:

    x -> norm -> MIXER(s) -> +residual -> norm -> CHANNEL-MLP -> +residual

where MIXER is GQA attention / MLA attention / parallel attn+SSD heads /
mLSTM / sLSTM / cross-attention, and CHANNEL-MLP is a dense (Swi)GLU or a
routed MoE.  Layers are grouped into uniform *scan groups* (see
repro/models/lm.py) so the whole stack lowers as one ``lax.scan`` per kind.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared experts (deepseek-v2 style), each d_ff_expert wide
    capacity_factor: float = 1.25
    router_softmax: bool = True  # False -> sigmoid scores (llama4-style)
    every_k: int = 1  # MoE on every k-th layer (llama4 interleaves dense/MoE)
    dispatch: str = "fmi"  # fmi (shard_map EP) | scatter | einsum (GShard)


@dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    kind: str = "mlstm"  # 'mlstm' (xlstm) | 'ssd' (mamba-2 style, hymba heads)
    proj_factor: float = 2.0  # d_inner = proj_factor * d_model (mlstm)
    conv_kernel: int = 4
    state_size: int = 16  # ssd state per head
    slstm_every: int = 4  # xlstm: every k-th block is an sLSTM block
    n_ssm_heads: int = 0  # hymba: SSD heads running parallel to attention


@dataclass(frozen=True)
class VLMCfg:
    cross_every: int = 5  # every 5th layer is a cross-attention layer
    n_vision_tokens: int = 1601  # stub frontend supplies [B, n_vis, d_model]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    causal: bool = True  # False: encoder-only (hubert)
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    vlm: Optional[VLMCfg] = None
    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # stored parameter dtype
    # training details
    remat: bool = True
    scan_layers: bool = True

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        """Layers per scan group (uniform unrolled body inside lax.scan)."""
        if self.family == "vlm" and self.vlm:
            return self.vlm.cross_every
        if self.family == "ssm" and self.ssm and self.ssm.kind == "mlstm":
            return self.ssm.slstm_every
        if self.family == "moe" and self.moe:
            return self.moe.every_k
        return 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            f"{self.name}: n_layers={self.n_layers} % group={self.group_size}"
        )
        return self.n_layers // self.group_size

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def supports_decode(self) -> bool:
        return self.causal  # encoder-only archs have no autoregressive step

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts with bounded state?"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return self.sliding_window > 0
        return False

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test-sized variant of the same family (CPU-runnable)."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 * self.group_size),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=256,
            vocab_size=512,
            head_dim=32,
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2), d_ff_expert=64
            )
        if self.mla:
            kw["mla"] = MLACfg(kv_lora=32, q_lora=64, qk_nope=32, qk_rope=16, v_dim=32)
        if self.ssm:
            kw["ssm"] = replace(self.ssm, state_size=8,
                                n_ssm_heads=2 if self.ssm.n_ssm_heads else 0)
        if self.vlm:
            kw["vlm"] = replace(self.vlm, n_vision_tokens=16)
        if self.sliding_window:
            kw["sliding_window"] = 32
        kw["param_dtype"] = "float32"
        kw["dtype"] = "float32"
        kw.update(over)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Parameter / FLOP accounting (roofline MODEL_FLOPS = 6·N·D per token)
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (exact for our implementation)."""
    from . import lm  # late import to avoid cycle

    return lm.count_params(cfg)


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: shared + top_k experts only)."""
    from . import lm

    return lm.count_params(cfg, active_only=True)
