"""Channel MLPs: dense (Swi)GLU / GELU, and routed mixture-of-experts.

MoE dispatch has two implementations (config ``dispatch``):

* ``einsum`` — GShard-style capacity-based dispatch: a [B, S, E, C] one-hot
  routes tokens into a [B, E, C, D] buffer with one einsum per top-k slot.
  Partitions perfectly under GSPMD (E over the model axis → all-to-all),
  but the dispatch/combine einsums are real MXU FLOPs (≈ doubles MoE cost).
* ``scatter`` — sort-free scatter-add into the [B, E·C, D] buffer + gather
  combine; no dispatch FLOPs, but leans on GSPMD's scatter partitioning.

The §Perf hillclimb compares both on the compiled HLO (see EXPERIMENTS.md).

Expert parallelism: the expert dim E is sharded over the 'model' mesh axis
(EP); tokens cross that axis via the all-to-all GSPMD derives from the
sharding constraints.  Capacity is per sequence: C = ceil(S·k/E · factor);
overflow tokens are dropped (their residual passes through — standard
capacity-based MoE semantics).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from .config import ModelConfig
from .layers import Axes, dense_init, swiglu


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":  # hubert: classic 2-matrix GELU MLP
        return {
            "up": dense_init(ks[0], (D, F), cfg.pdtype),
            "down": dense_init(ks[1], (F, D), cfg.pdtype),
        }
    return {
        "gate": dense_init(ks[0], (D, F), cfg.pdtype),
        "up": dense_init(ks[1], (D, F), cfg.pdtype),
        "down": dense_init(ks[2], (F, D), cfg.pdtype),
    }


def mlp_apply(p, x, cfg: ModelConfig, ax: Axes):
    dt = cfg.adtype
    if "gate" in p:
        h = swiglu(x @ p["gate"].astype(dt), x @ p["up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["up"].astype(dt))
    h = ax.act_btf(h)
    return ax.act_btd(h @ p["down"].astype(dt))


# ---------------------------------------------------------------------------
# routed MoE
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "gate": dense_init(ks[1], (E, D, Fe), cfg.pdtype, fan_in=D),
        "up": dense_init(ks[2], (E, D, Fe), cfg.pdtype, fan_in=D),
        "down": dense_init(ks[3], (E, Fe, D), cfg.pdtype, fan_in=Fe),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.n_shared * Fe)
    return p


def _route(p, x, cfg: ModelConfig):
    """Returns (weights [B,S,K], expert ids [B,S,K], aux load-balance loss)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if m.router_softmax:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    else:  # llama4-style sigmoid scores
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, m.top_k)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    E = m.n_experts
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=(0, 1))  # routed fraction
    aux = E * jnp.sum(me * ce)
    return w.astype(x.dtype), idx, aux


def moe_apply(p, x, cfg: ModelConfig, ax: Axes, dispatch: str | None = None):
    """x: [B, S, D] -> [B, S, D].  Returns (out, aux_loss).

    ``fmi`` dispatch (default for EP archs): explicit shard_map over the
    model axis.  x is TP-replicated when it reaches the MoE, so each shard
    scatters *locally* into its own experts' [E_loc, C, D] buffer (zero
    dispatch communication and zero dispatch FLOPs) and the partial outputs
    meet in ONE allreduce of [B, S, D] per layer — the same wire cost as a
    Megatron MLP.  GShard 'einsum' (dispatch-FLOPs-heavy) and global
    'scatter' (GSPMD-partitioning-hostile) are kept for the §Perf ablation.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K, Fe = m.n_experts, m.top_k, m.d_ff_expert
    C = max(1, math.ceil(S * K / E * m.capacity_factor))
    dt = cfg.adtype
    if dispatch is None:
        dispatch = m.dispatch

    w, idx, aux = _route(p, x, cfg)
    e_axis = ax.model if ax.divides(E, ax.model) else None
    if dispatch == "fmi" and (e_axis is None or ax.axsize(ax.model) <= 1):
        dispatch = "scatter"  # no EP axis available (single device / tests)

    # slot positions: for each (s, k) routed pair, its position within the
    # expert's capacity buffer (counted over the flattened (s, k) stream)
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [B, S, K, E]
    flat = oh.reshape(B, S * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat  # [B, S*K, E]
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(B, S, K)  # [B, S, K]
    keep = pos < C

    gate_w, up_w, down_w = (p[n].astype(dt) for n in ("gate", "up", "down"))

    if dispatch == "fmi":
        out = _moe_fmi(
            p, x, idx, w, pos, keep, cfg, ax, C, gate_w, up_w, down_w
        )
    elif dispatch == "einsum":
        buf = jnp.zeros((B, E, C, D), dt)
        for k in range(K):  # K small (<= 6); per-slot einsum keeps temps ~[B,S,E,C]
            d_k = (
                jax.nn.one_hot(idx[:, :, k], E, dtype=dt)
                * keep[:, :, k : k + 1].astype(dt)
            )  # [B, S, E]
            slot_k = jax.nn.one_hot(pos[:, :, k], C, dtype=dt)  # [B, S, C]
            disp = jnp.einsum("bse,bsc->bsec", d_k, slot_k)
            buf = buf + jnp.einsum("bsec,bsd->becd", disp, x)
        buf = ax.constrain(buf, P(ax.data, e_axis, None, None))
        h = swiglu(
            jnp.einsum("becd,edf->becf", buf, gate_w),
            jnp.einsum("becd,edf->becf", buf, up_w),
        )
        h = ax.constrain(h, P(ax.data, e_axis, None, None))
        eout = jnp.einsum("becf,efd->becd", h, down_w)  # [B, E, C, D]
        eout = ax.constrain(eout, P(ax.data, e_axis, None, None))
        out = jnp.zeros((B, S, D), dt)
        for k in range(K):
            d_k = (
                jax.nn.one_hot(idx[:, :, k], E, dtype=dt)
                * keep[:, :, k : k + 1].astype(dt)
                * w[:, :, k : k + 1]
            )
            slot_k = jax.nn.one_hot(pos[:, :, k], C, dtype=dt)
            comb = jnp.einsum("bse,bsc->bsec", d_k, slot_k)
            out = out + jnp.einsum("bsec,becd->bsd", comb, eout)
    elif dispatch == "scatter":
        # flat target slot e*C + c (dropped tokens land in a trash row E*C)
        tgt = jnp.where(keep, idx * C + pos, E * C).reshape(B, S * K)  # [B, S*K]
        x_rep = jnp.repeat(x, K, axis=1)  # [B, S*K, D]
        buf = jnp.zeros((B, E * C + 1, D), dt)
        buf = buf.at[jnp.arange(B)[:, None], tgt].add(x_rep)
        buf = buf[:, : E * C].reshape(B, E, C, D)
        buf = ax.constrain(buf, P(ax.data, e_axis, None, None))
        h = swiglu(
            jnp.einsum("becd,edf->becf", buf, gate_w),
            jnp.einsum("becd,edf->becf", buf, up_w),
        )
        eout = jnp.einsum("becf,efd->becd", h, down_w).reshape(B, E * C, D)
        eout = jnp.concatenate([eout, jnp.zeros((B, 1, D), dt)], axis=1)
        picked = eout[jnp.arange(B)[:, None], tgt].reshape(B, S, K, D)
        out = jnp.einsum("bskd,bsk->bsd", picked, w * keep.astype(dt))
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")

    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg, ax)
    return ax.act_btd(out), aux


def _moe_fmi(p, x, idx, w, pos, keep, cfg: ModelConfig, ax: Axes, C: int,
             gate_w, up_w, down_w):
    """Fully-manual EP block: shard_map over (data axes + model).

    Each chip: (1) FMI-allgathers its experts' FSDP weight shards over the
    data axis (ring ppermutes — differentiable, so the backward is the
    matching reduce-scatter for free), (2) scatters its *local batch shard*
    tokens into its own experts' [E_loc, C, D] buffer — no dispatch
    communication, since x is replicated over the model axis — and
    (3) psums the partial outputs over the model axis: one activation
    allreduce per layer, the same wire bytes as a Megatron MLP.
    """
    from ..core import collectives as COLL
    from ..core.communicator import Communicator

    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    dt = cfg.adtype
    tp = ax.axsize(ax.model)
    E_loc = E // tp
    w_keep = (w * keep.astype(dt)).astype(dt)

    # tokens whole (not sequence-sharded) entering the EP region
    tok_spec = P(ax.data, None, None) if ax.data else P(None, None, None)
    x = ax.constrain(x, tok_spec)
    idx = ax.constrain(idx, tok_spec)
    w_keep = ax.constrain(w_keep, tok_spec)
    pos = ax.constrain(pos, tok_spec)

    fsdp_axes = tuple(a for a in ax.fsdp if a != ax.model)
    fsdp_deg = ax.axsize(fsdp_axes) if fsdp_axes else 1
    gather_weights = fsdp_deg > 1
    comm_fsdp = (
        Communicator(axes=fsdp_axes, sizes=tuple(ax.sizes[a] for a in fsdp_axes))
        if gather_weights
        else None
    )
    w_spec = P(ax.model, fsdp_axes if gather_weights else None, None)
    manual = set(ax.data) | {ax.model} | set(fsdp_axes)

    def gather_dim1(wl, full_dim1: int):
        """FMI-allgather the FSDP-sharded dim-1 of an expert weight."""
        if not gather_weights:
            return wl
        e, d_loc, f = wl.shape
        flat = COLL.allgather(wl.reshape(-1), comm_fsdp, algorithm="ring")
        fullw = flat.reshape(fsdp_deg, e, d_loc, f)
        return jnp.moveaxis(fullw, 0, 1).reshape(e, fsdp_deg * d_loc, f)

    def body(xl, idxl, wl, posl, gw, uw, dw):
        b_loc = xl.shape[0]
        gw = gather_dim1(gw, D)
        uw = gather_dim1(uw, D)
        dw = gather_dim1(dw, m.d_ff_expert)
        rank = jax.lax.axis_index(ax.model)
        base = rank * E_loc
        local = (idxl >= base) & (idxl < base + E_loc)
        tgt = jnp.where(local, (idxl - base) * C + posl, E_loc * C)  # [b,S,K]
        rows = jnp.arange(b_loc)[:, None]
        buf = jnp.zeros((b_loc, E_loc * C + 1, D), dt)
        for k in range(K):  # per-slot scatter: transients stay [b, S, D]
            buf = buf.at[rows, tgt[:, :, k]].add(xl)
        buf = buf[:, : E_loc * C].reshape(b_loc, E_loc, C, D)
        h = swiglu(
            jnp.einsum("becd,edf->becf", buf, gw),
            jnp.einsum("becd,edf->becf", buf, uw),
        )
        eout = jnp.einsum("becf,efd->becd", h, dw).reshape(b_loc, E_loc * C, D)
        eout = jnp.concatenate([eout, jnp.zeros((b_loc, 1, D), dt)], axis=1)
        part = jnp.zeros((b_loc, S, D), dt)
        for k in range(K):
            picked = eout[rows, tgt[:, :, k]]  # [b, S, D]
            part = part + picked * (wl[:, :, k] * local[:, :, k].astype(dt))[..., None]
        # NB: psum stays in the activation dtype — an f32 upcast here poisons
        # the whole backward into f32 (f32 expert-grad stacks, ~3x memory).
        # XLA:CPU's all-reduce-promotion pass crashes on some bf16
        # all-reduces; the dry-run disables that pass (see launch/dryrun.py).
        return jax.lax.psum(part, ax.model)

    return compat.shard_map(
        body,
        in_specs=(tok_spec, tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
        out_specs=tok_spec,
        axis_names=manual,
        check_vma=False,
    )(x, idx, w_keep, pos, gate_w, up_w, down_w)
