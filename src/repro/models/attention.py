"""GQA self-attention, cross-attention, and KV caches (full + ring).

Cache kinds:

* ``full`` — contiguous [B, S_max, Hkv, hd]; decode writes at position ``t``
  and attends over the whole buffer with a causal mask (garbage beyond ``t``
  is masked).  Used by every full-attention arch.
* ``ring`` — sliding-window ring buffer [B, W, Hkv, hd] plus an absolute
  position array [B, W]; decode writes at ``t % W``.  O(W) memory at any
  context length — this is what makes hymba's 500k-token decode cell
  feasible.  (xlstm needs no cache at all.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import Axes, apply_rope, dense_init, rmsnorm


def attn_init(key, cfg: ModelConfig, cross: bool = False):
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (D, Hkv * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (Hq * hd, D), cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    if cross:
        p["gate"] = jnp.zeros((), cfg.pdtype)  # tanh-gated residual (llama-vision)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str = "full"):
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    if kind == "ring":
        W = cfg.sliding_window
        return {
            "k": jnp.zeros((batch, W, Hkv, hd), cfg.adtype),
            "v": jnp.zeros((batch, W, Hkv, hd), cfg.adtype),
            "pos": jnp.full((batch, W), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, Hkv, hd), cfg.adtype),
        "v": jnp.zeros((batch, max_len, Hkv, hd), cfg.adtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _merge_heads(x):
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


def _qkv(p, cfg: ModelConfig, x, kv_src, positions, ax: Axes, rope: bool):
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.adtype
    q = _split_heads(x @ p["wq"].astype(dt), Hq, hd)
    src = x if kv_src is None else kv_src
    k = _split_heads(src @ p["wk"].astype(dt), Hkv, hd)
    v = _split_heads(src @ p["wv"].astype(dt), Hkv, hd)
    q, k, v = ax.act_bthd(q), ax.act_bthd(k), ax.act_bthd(v)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_src is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(
    p,
    x,  # [B, T, D]
    cfg: ModelConfig,
    ax: Axes,
    *,
    kv_src=None,  # cross-attention source [B, N, D] (no rope on kv)
    positions=None,  # [T] absolute positions of x
    cache=None,
    decode_pos=None,  # scalar absolute position (decode mode)
    backend: str = "auto",
):
    """Returns (out [B,T,D], new_cache)."""
    B, T, D = x.shape
    cross = kv_src is not None
    causal = cfg.causal and not cross
    window = 0 if cross else cfg.sliding_window
    if positions is None:
        positions = (
            jnp.arange(T) if decode_pos is None else jnp.full((T,), decode_pos)
        )
    q, k, v = _qkv(p, cfg, x, kv_src, positions, ax, rope=not cross)

    # a "decode step" is a single-token continuation; prefill (T > 1) writes
    # the cache but attends within x itself
    is_step = decode_pos is not None and T == 1

    new_cache = cache
    if cache is not None and not cross:
        if "pos" in cache:  # ring buffer (sliding window)
            W = cache["k"].shape[1]
            if not is_step:  # prefill: write last W tokens
                take = min(T, W)
                idx = (positions[-take:]) % W
                new_cache = {
                    "k": cache["k"].at[:, idx].set(k[:, -take:]),
                    "v": cache["v"].at[:, idx].set(v[:, -take:]),
                    "pos": cache["pos"].at[:, idx].set(
                        jnp.broadcast_to(positions[-take:], (B, take))
                    ),
                }
            else:
                slot = decode_pos % W
                new_cache = {
                    "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1),
                    "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1),
                    "pos": jax.lax.dynamic_update_slice_in_dim(
                        cache["pos"],
                        jnp.full((B, 1), decode_pos, jnp.int32),
                        slot,
                        1,
                    ),
                }
        else:  # full cache
            at = 0 if decode_pos is None else decode_pos
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, at, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, at, 1),
            }

    # ---- attend ----
    if is_step and cache is not None and not cross:
        if "pos" in new_cache:
            out = _ring_attend(q, new_cache, cfg, decode_pos)
        else:
            # direct masked attention: one token against the whole cache.
            # (flash chunking buys nothing at T=1 and its reshapes reshard a
            # sequence-sharded cache — measured in the §Perf log)
            out = _full_cache_attend(q, new_cache, cfg, decode_pos, window)
    else:
        src_k, src_v = k, v
        out = ops.flash_attention(
            jnp.swapaxes(q, 1, 2),
            jnp.swapaxes(src_k, 1, 2),
            jnp.swapaxes(src_v, 1, 2),
            causal=causal, window=window, q_offset=0, backend=backend,
        )
        out = jnp.swapaxes(out, 1, 2)

    out = ax.act_bthd(out)
    out = _merge_heads(out) @ p["wo"].astype(cfg.adtype)
    if cross:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return ax.act_btd(out), new_cache


def _full_cache_attend(q, cache, cfg: ModelConfig, t, window: int):
    """Decode attention: q [B, 1, Hq, hd] vs cache [B, S, Hkv, hd].

    Scores/softmax in f32 via preferred_element_type (no materialized f32
    K/V copies); positions beyond ``t`` masked.  The S dim may be sharded
    over the model axis — the max/sum reductions and the weighted sum
    partition into per-shard partials + tiny all-reduces under GSPMD
    (sequence-parallel decode attention)."""
    B, _, Hq, hd = q.shape
    kc, vc = cache["k"], cache["v"]  # [B, S, Hkv, hd]
    S = kc.shape[1]
    Hkv = kc.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, hd)  # T=1 folded; q heads grouped per kv head
    s = jax.lax.dot_general(
        qg, kc, (((3,), (3,)), ((0, 1), (0, 2))), preferred_element_type=jnp.float32
    )  # contract hd; batch (B, Hkv) -> [B, Hkv, group, S]
    s = s * (hd**-0.5)
    k_pos = jnp.arange(S)[None, None, None, :]
    mask = k_pos <= t
    if window:
        mask = mask & (k_pos > t - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jax.lax.dot_general(
        p.astype(q.dtype), vc, (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32,
    )  # [B, Hkv, group, hd]
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


def _ring_attend(q, cache, cfg: ModelConfig, t):
    """Decode attention over a ring buffer: q [B, 1, Hq, hd]."""
    B, _, Hq, hd = q.shape
    Hkv = cfg.n_kv_heads
    group = Hq // Hkv
    kc, vc, pos = cache["k"], cache["v"], cache["pos"]  # [B, W, Hkv, hd], [B, W]
    qf = q.astype(jnp.float32) * (hd**-0.5)
    kf = jnp.repeat(kc.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(vc.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bthd,bwhd->bhtw", qf, kf)  # [B, Hq, 1, W]
    valid = (pos >= 0) & (pos <= t) & (pos > t - cfg.sliding_window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhtw,bwhd->bthd", pr, vf)
    return out.astype(q.dtype)
