"""Model zoo: unified block machinery covering all ten assigned archs."""

from . import attention, lm, mla, moe, ssm
from .config import MLACfg, MoECfg, ModelConfig, SSMCfg, VLMCfg
from .layers import NO_SHARD, Axes

__all__ = [
    "ModelConfig", "MoECfg", "MLACfg", "SSMCfg", "VLMCfg",
    "Axes", "NO_SHARD",
    "lm", "attention", "moe", "mla", "ssm",
]
