"""Recurrent mixers: xLSTM (mLSTM + sLSTM) and SSD heads (hymba).

All O(T) in sequence length with O(1) decode state — these are the archs
that run the 500k-token decode cell.

* **mLSTM** (matrix memory): chunk-parallel via the GLA Pallas kernel
  (repro.kernels.ssm_scan); decode is a 3-op recurrent update.
  Deviation from the paper recorded in DESIGN.md: the running-max
  stabilizer m_t is replaced by clipping the exponential input gate
  pre-activation (chunked-matmul-friendly) + the max(|q·n|,1) normalizer.
* **sLSTM** (scalar memory, recurrent R): inherently sequential —
  implemented as a lax.scan over time with exponential-gating
  stabilization.  xlstm-125m places one sLSTM block every
  ``ssm.slstm_every`` blocks.
* **SSD** (mamba-2-style scalar-decay state space): hymba's second head
  set, running in parallel with sliding-window attention.  Deviation:
  hymba's mamba-1 heads are expressed in the SSD (scalar per-head decay)
  form — TPU-native chunked matmuls instead of a per-channel selective
  scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import Axes, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# causal conv1d (shared helper; kernel k, per-channel)
# ---------------------------------------------------------------------------


def conv1d_init(key, channels: int, k: int, dtype):
    return {"w": dense_init(key, (k, channels), dtype, fan_in=k)}


def conv1d_apply(p, x, state=None):
    """x: [B, T, C] causal depthwise conv.  state: [B, k-1, C] carry for
    decode.  Returns (y, new_state)."""
    w = p["w"].astype(x.dtype)  # [k, C]
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    s = cfg.ssm
    di = int(s.proj_factor * D)
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((D,), cfg.pdtype),
        "up": dense_init(ks[0], (D, 2 * di), cfg.pdtype),
        "conv": conv1d_init(ks[1], di, s.conv_kernel, cfg.pdtype),
        "wq": dense_init(ks[2], (di, di), cfg.pdtype),
        "wk": dense_init(ks[3], (di, di), cfg.pdtype),
        "wv": dense_init(ks[4], (di, di), cfg.pdtype),
        "wif": dense_init(ks[5], (di, 2 * H), cfg.pdtype),
        "out_norm": jnp.ones((di,), cfg.pdtype),
        "down": dense_init(ks[6], (di, D), cfg.pdtype),
    }


def _mlstm_gates(pre, H):
    """pre: [B, T, 2H] -> (log_f [B,H,T], i [B,H,T]) stabilized."""
    f_pre, i_pre = pre[..., :H], pre[..., H:]
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i_gate = jnp.exp(jnp.clip(i_pre.astype(jnp.float32), -10.0, 2.0))
    return jnp.moveaxis(log_f, -1, 1), jnp.moveaxis(i_gate, -1, 1)


def mlstm_apply(p, x, cfg: ModelConfig, ax: Axes, state=None, backend="auto"):
    """x: [B, T, D].  state (decode): dict(C [B,H,dk,dv+1], conv [B,k-1,di]).
    Returns (out, new_state)."""
    s = cfg.ssm
    B, T, D = x.shape
    H = cfg.n_heads
    di = int(s.proj_factor * D)
    dk = di // H
    dt = cfg.adtype

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = h @ p["up"].astype(dt)
    xm, z = up[..., :di], up[..., di:]
    conv_state = None if state is None else state.get("conv")
    xc, new_conv = conv1d_apply(p["conv"], xm, conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)

    def heads(y):
        return jnp.moveaxis(y.reshape(B, T, H, dk), 2, 1)  # [B, H, T, dk]

    q = heads(xc @ p["wq"].astype(dt))
    k = heads(xc @ p["wk"].astype(dt))
    v = heads(xm @ p["wv"].astype(dt))
    log_f, i_gate = _mlstm_gates(xm @ p["wif"].astype(dt), H)

    if state is None or T > 1:
        out, C = ops.gla_scan(q, k, v, log_f, i_gate, normalize=True, backend=backend)
        new_C = C
    else:
        # recurrent single-step decode
        C = state["C"]  # [B, H, dk, dv+1] f32
        qf = q[:, :, 0].astype(jnp.float32) * (dk**-0.5)
        kf = k[:, :, 0].astype(jnp.float32)
        vf = v[:, :, 0].astype(jnp.float32)
        ff = jnp.exp(log_f[:, :, 0])[..., None, None]
        ii = i_gate[:, :, 0][..., None, None]
        v_aug = jnp.concatenate([vf, jnp.ones_like(vf[..., :1])], -1)
        new_C = ff * C + ii * (kf[..., :, None] * v_aug[..., None, :])
        num = jnp.einsum("bhk,bhkv->bhv", qf, new_C)
        den = jnp.maximum(jnp.abs(num[..., -1:]), 1.0)
        out = (num[..., :-1] / den)[:, :, None, :].astype(dt)  # [B,H,1,dv]

    out = jnp.moveaxis(out, 1, 2).reshape(B, T, di)
    out = rmsnorm(out, p["out_norm"], cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    y = out @ p["down"].astype(dt)
    new_state = {"C": new_C, "conv": new_conv}
    return ax.act_btd(x + y), new_state


def mlstm_init_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = int(s.proj_factor * cfg.d_model)
    H = cfg.n_heads
    dk = di // H
    return {
        "C": jnp.zeros((batch, H, dk, dk + 1), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di), cfg.adtype),
    }


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential lax.scan
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 4)
    ffd = max(1, int(4 / 3 * D))
    return {
        "norm": jnp.ones((D,), cfg.pdtype),
        "wx": dense_init(ks[0], (D, 4 * D), cfg.pdtype),  # i,f,z,o pre-acts
        "r": dense_init(ks[1], (H, dh, 4 * dh), cfg.pdtype, fan_in=dh),
        "ffn_up": dense_init(ks[2], (D, ffd), cfg.pdtype),
        "ffn_down": dense_init(ks[3], (ffd, D), cfg.pdtype),
        "ffn_norm": jnp.ones((D,), cfg.pdtype),
    }


def slstm_step(p, cfg: ModelConfig, carry, wx_t):
    """carry: (h [B,D], c, n, m); wx_t: [B, 4D] input pre-activations."""
    H = cfg.n_heads
    D = cfg.d_model
    dh = D // H
    h, c, n, m = carry
    rh = jnp.einsum("bhd,hde->bhe", h.reshape(-1, H, dh), p["r"].astype(h.dtype))
    pre = (wx_t.reshape(-1, H, 4 * dh) + rh).astype(jnp.float32)
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_p + m, i_p)  # per-unit stabilizer
    i = jnp.exp(i_p - m_new)
    f = jnp.exp(f_p + m - m_new)
    c = f * c + i * jnp.tanh(z_p)
    n = f * n + i
    h_new = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1.0)
    return (h_new.reshape(-1, D).astype(h.dtype), c, n, m_new)


def slstm_apply(p, x, cfg: ModelConfig, ax: Axes, state=None):
    """x: [B, T, D]; sequential over T.  state: (h, c, n, m) for decode."""
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    dt = cfg.adtype
    h0 = rmsnorm(x, p["norm"], cfg.norm_eps)
    wx = h0 @ p["wx"].astype(dt)  # [B, T, 4D]
    if state is None:
        state = (
            jnp.zeros((B, D), dt),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H, dh), -1e30, jnp.float32),
        )

    def step(carry, wx_t):
        new = slstm_step(p, cfg, carry, wx_t)
        return new, new[0]

    new_state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)  # [B, T, D]
    x = x + y
    # post-FFN (proj factor 4/3, gelu)
    f = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
    f = jax.nn.gelu((f @ p["ffn_up"].astype(dt)).astype(jnp.float32)).astype(dt)
    x = x + f @ p["ffn_down"].astype(dt)
    return ax.act_btd(x), new_state


def slstm_init_state(cfg: ModelConfig, batch: int):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    return (
        jnp.zeros((batch, D), cfg.adtype),
        jnp.zeros((batch, H, dh), jnp.float32),
        jnp.zeros((batch, H, dh), jnp.float32),
        jnp.full((batch, H, dh), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# SSD heads (hymba): mamba-2-style scalar-decay state space
# ---------------------------------------------------------------------------


def ssd_init(key, cfg: ModelConfig):
    D = cfg.d_model
    s = cfg.ssm
    H = s.n_ssm_heads
    hd = D // H
    N = s.state_size
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (D, H * (hd + 2 * N + 1) + H * hd), cfg.pdtype),
        "conv": conv1d_init(ks[1], H * (hd + 2 * N), s.conv_kernel, cfg.pdtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": jnp.ones((H * hd,), cfg.pdtype),
    }


def ssd_apply(p, x, cfg: ModelConfig, ax: Axes, state=None, backend="auto"):
    """Returns (y [B, T, H*hd], new_state {C, conv})."""
    s = cfg.ssm
    B, T, D = x.shape
    H = s.n_ssm_heads
    hd = D // H
    N = s.state_size
    dt_ = cfg.adtype

    proj = x @ p["in_proj"].astype(dt_)
    core, z, dt_pre = (
        proj[..., : H * (hd + 2 * N)],
        proj[..., H * (hd + 2 * N) : H * (hd + 2 * N) + H * hd],
        proj[..., -H:],
    )
    conv_state = None if state is None else state.get("conv")
    core, new_conv = conv1d_apply(p["conv"], core, conv_state)
    core = jax.nn.silu(core.astype(jnp.float32)).astype(dt_)
    core = core.reshape(B, T, H, hd + 2 * N)
    v = jnp.moveaxis(core[..., :hd], 2, 1)  # [B, H, T, hd]
    k = jnp.moveaxis(core[..., hd : hd + N], 2, 1)  # B_ssm
    q = jnp.moveaxis(core[..., hd + N :], 2, 1)  # C_ssm

    delta = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])  # [B,T,H]
    delta = jnp.moveaxis(delta, -1, 1)  # [B, H, T]
    A = jnp.exp(p["A_log"])[None, :, None]  # [1, H, 1] > 0
    log_f = -delta * A
    i_gate = delta

    if state is None or T > 1:
        out, C = ops.gla_scan(q, k, v, log_f, i_gate, normalize=False, backend=backend)
        new_C = C
    else:
        C = state["C"]  # [B, H, N, hd+1]
        qf = q[:, :, 0].astype(jnp.float32) * (N**-0.5)
        kf = k[:, :, 0].astype(jnp.float32)
        vf = v[:, :, 0].astype(jnp.float32)
        v_aug = jnp.concatenate([vf, jnp.ones_like(vf[..., :1])], -1)
        ff = jnp.exp(log_f[:, :, 0])[..., None, None]
        ii = i_gate[:, :, 0][..., None, None]
        new_C = ff * C + ii * (kf[..., :, None] * v_aug[..., None, :])
        out = jnp.einsum("bhk,bhkv->bhv", qf, new_C)[..., :-1][:, :, None, :].astype(dt_)

    out = out + p["D_skip"].astype(dt_)[None, :, None, None] * v
    y = jnp.moveaxis(out, 1, 2).reshape(B, T, H * hd)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    return y, {"C": new_C, "conv": new_conv}


def ssd_init_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    H = s.n_ssm_heads
    hd = cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, s.state_size, hd + 1), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, H * (hd + 2 * s.state_size)), cfg.adtype),
    }
